"""GES frontier-scoring throughput: sequential per-candidate dispatch vs
the batched engine (feature bank + Gram-block cache + chunked fold algebra).

For each (d, n) cell the benchmark builds the sweep-1 GES frontier on
synthetic SCM data — every Insert(X, Y, {}) needs (y, {x}) and (y, {})
local scores, d^2 configurations total — and measures candidate-scores/sec
through both paths of the SAME scorer state (features prebuilt, jit warm,
so the comparison isolates the scoring engine).  Since PR 3 each cell also
times the batched engine with the device-bank tier disabled
(``device_bank_mb=0`` — the PR-2 host-assembly path) and records a
per-stage wall split (Gram / z-cores / fold) for both engine paths via the
`repro.obs` span layer (`engine_stage_split` over a trace Recorder), so
the fold-stage host-assembly cost the device-resident pipeline removes
stays visible in the json.  Emits
BENCH_frontier.json at the repo root so future PRs track the trajectory.

``python -m benchmarks.frontier_scoring``            — full grid
``python -m benchmarks.frontier_scoring --quick``    — small cells only
``--precision``  — additionally time the ``precision="f32_gram"`` policy
(`repro.core.spec.EngineOptions`): cold/warm rates of the engine with
f32 Gram accumulation, plus its max |score - f64 oracle| deviation
(absolute and relative) against the bitwise engine, which on CPU *is*
the f64 oracle.  Never run concurrently with the test suite.
``--check-speedup X``  — exit nonzero unless every cell's batched/seq
ratio is >= X (the CI perf-smoke gate: engine regressions fail loudly).
``--check-warm-speedup X``  — exit nonzero unless every cell's
incremental warm-sweep rate (configs served per second across the
steady-state delta sweeps of a `DiscoverySession` driven on the sweep
seam) is >= X times its cold full-frontier rate — the PR-8 gate that the
frontier-delta engine actually pays for itself.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from benchmarks._writer import write_bench

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(REPO_ROOT, "BENCH_frontier.json")


def _frontier_configs(d: int):
    configs = [(y, ()) for y in range(d)]
    configs += [(y, (x,)) for x in range(d) for y in range(d) if x != y]
    return configs


def _bench_cell(
    d: int, n: int, seq_cap: int, seed: int = 0, precision: bool = False
) -> dict:
    from repro.core.score_common import ScoreConfig, config_key
    from repro.core.score_lowrank import CVLRScorer
    from repro.core.spec import EngineOptions
    from repro.data.synthetic import generate_scm_data
    from repro.obs import Recorder, engine_stage_split
    from repro.obs import trace as obs_trace

    ds = generate_scm_data(d=d, n=n, density=0.3, kind="continuous", seed=seed)
    configs = _frontier_configs(d)

    scorer = CVLRScorer(ds.data, config=ScoreConfig(seed=seed))
    # Feature bank built once, outside the timers: both paths read the same
    # cached factors, so the cell measures scoring engines, not ICL.
    t0 = time.perf_counter()
    for v in range(d):
        scorer.features((v,))
    t_features = time.perf_counter() - t0
    m_effs = [scorer.m_eff_log[(v,)] for v in range(d)]
    feature_build_stats = dict(scorer.feature_bank.stats)

    # -- sequential oracle path: one jit dispatch + host sync per config --
    seq_configs = configs[: min(seq_cap, len(configs))]
    scorer._compute(*config_key(*configs[0]))  # jit warmup (not timed)
    seq_scores = []
    t0 = time.perf_counter()
    for i, ps in seq_configs:
        seq_scores.append(scorer._compute(*config_key(i, ps)))
    t_seq = time.perf_counter() - t0
    rate_seq = len(seq_configs) / t_seq

    def _mk(**kw):
        # every engine variant shares the prebuilt FeatureBank (PR 5): the
        # cell measures scoring engines, and the bank's counters at the end
        # prove the factors were built exactly once across all of them
        return CVLRScorer(
            ds.data, config=ScoreConfig(seed=seed),
            feature_bank=scorer.feature_bank, **kw,
        )

    def _timed_cold(**kw):
        """Warm the jit cache on one scorer, then time cold-cache runs
        (best of 3: the 2-vCPU box throws scheduler stragglers that would
        otherwise masquerade as engine regressions)."""
        _mk(**kw).prefetch(configs)  # compiles every chunk shape (not timed)
        best = None
        for _ in range(3):
            cold = _mk(**kw)
            t0 = time.perf_counter()
            n_done = cold.prefetch(configs)
            dt = time.perf_counter() - t0
            assert n_done == len(configs)
            best = dt if best is None else min(best, dt)
        return cold, len(configs) / best

    def _timed_warm(scorer):
        """Steady-state sweep: Gram cache fully hit (device-resident blocks
        on the bank path), only the fold stage runs.  Best of 2."""
        best = None
        for _ in range(2):
            scorer._score_cache.clear()
            t0 = time.perf_counter()
            scorer.prefetch(configs)
            best = min(best or 1e9, time.perf_counter() - t0)
        return len(configs) / best

    # -- batched engine, device-resident fold pipeline (the default) ------
    cold, rate_bat = _timed_cold()
    # snapshot BEFORE the warm sweeps below inflate the hit counters: the
    # recorded stats must keep describing the cold run, as in PR 1/2
    gram_stats = dict(cold.gram_cache.stats)
    rate_warm = _timed_warm(cold)
    # -- batched engine, host-assembly path (device banks off: PR-2) ------
    host_cold, rate_host = _timed_cold(device_bank_mb=0)
    rate_warm_host = _timed_warm(host_cold)
    # -- per-stage wall split, both paths (an active recorder makes the
    # engine sync at stage boundaries, so these are NOT the headline
    # rates; repro.obs.engine_stage_split folds the stage spans back
    # into the per-stage keys this json has carried since PR 2) -----------
    stage_split = {}
    for name, kw in (("device", {}), ("host", {"device_bank_mb": 0})):
        rec = Recorder(mode="trace")
        with obs_trace.use(rec):
            _mk(**kw).prefetch(configs)
        split = engine_stage_split(rec)
        assert split.pop("path") == name
        stage_split[name] = {k: round(v, 4) for k, v in split.items()}

    # -- opt-in: the f32_gram precision policy ----------------------------
    f32 = None
    if precision:
        opts = EngineOptions(precision="f32_gram")
        f32_cold, rate_f32 = _timed_cold(options=opts)
        rate_f32_warm = _timed_warm(f32_cold)
        # deviation vs the f64 oracle over the WHOLE frontier: on CPU the
        # default (bitwise) engine is bit-identical to the sequential f64
        # oracle, so its score cache is the oracle reference.
        max_abs = max_rel = 0.0
        for i, ps in configs:
            a = f32_cold._score_cache[config_key(i, ps)]
            b = cold._score_cache[config_key(i, ps)]
            max_abs = max(max_abs, abs(a - b))
            max_rel = max(max_rel, abs(a - b) / max(1.0, abs(b)))
        f32 = {
            "cold_scores_per_sec": round(rate_f32, 3),
            "warm_sweep_scores_per_sec": round(rate_f32_warm, 3),
            "speedup_vs_bitwise_cold": round(rate_f32 / rate_bat, 3),
            "max_abs_dev_vs_f64_oracle": max_abs,
            "max_rel_dev_vs_f64_oracle": max_rel,
            "policy_oracle_rtol": opts.oracle_rtol,
        }
        assert max_rel <= opts.oracle_rtol, (
            f"f32_gram deviated {max_rel:.2e} > policy bound {opts.oracle_rtol}"
        )

    # -- incremental frontier-delta sweeps on the session seam (PR 8) ----
    incremental = _bench_incremental(ds.data, d, seed, scorer.feature_bank)

    # numerical agreement spot-check (engine == oracle)
    worst = 0.0
    for (i, ps), b in zip(seq_configs, seq_scores):
        a = cold._score_cache[config_key(i, ps)]
        worst = max(worst, abs(a - b) / max(1.0, abs(b)))

    return {
        "d": d,
        "n": n,
        "n_configs": len(configs),
        "n_seq_timed": len(seq_configs),
        "m_eff_range": [int(min(m_effs)), int(max(m_effs))],
        "feature_build_s": round(t_features, 4),
        # the feature-build stage split out (PR 5): `build` is the cold
        # per-factor build cost, `reused` the bank stats after every engine
        # variant above ran off the same bank — builds stays at d, so the
        # rebuild saving per extra sweep/scorer is the whole build_s
        "feature_bank": {
            "build": feature_build_stats,
            "after_all_paths": dict(scorer.feature_bank.stats),
        },
        "seq_scores_per_sec": round(rate_seq, 3),
        "batched_scores_per_sec": round(rate_bat, 3),
        "batched_hostpath_scores_per_sec": round(rate_host, 3),
        "warm_sweep_scores_per_sec": round(rate_warm, 3),
        "warm_sweep_hostpath_scores_per_sec": round(rate_warm_host, 3),
        "speedup": round(rate_bat / rate_seq, 3),
        "device_vs_hostpath": round(rate_bat / rate_host, 3),
        "stage_split_s": stage_split,
        "max_rel_err": worst,
        "gram_cache": gram_stats,
        "incremental": incremental,
        **({"f32_gram": f32} if f32 is not None else {}),
    }


def _bench_incremental(data, d: int, seed: int, feature_bank) -> dict:
    """Warm vs cold sweep rate through the incremental session seam.

    Drives a `DiscoverySession`'s `begin_sweep` / `score_frontier` /
    `end_sweep` directly — sweep 0 is the cold full frontier, then each
    "applied step" adds ~d fresh configs for one node, the shape of a
    real GES sweep-over-sweep delta.  Per-sweep delta/carried counters
    come from the session's own sweep log; the headline warm rate is
    frontier-configs-SERVED per second (carried configs are served from
    the score memo — that is the point of the engine) over the
    steady-state sweeps.

    Like every other cell in this benchmark, the timed pass runs on a
    pre-warmed jit cache: an untimed session first walks the *identical*
    sweep schedule, compiling both the cold full-frontier shapes and the
    warm small-batch delta shapes, then a fresh session (empty score
    memo, same process-global jit cache) is timed.  Without the warmup
    the comparison is skewed both ways at once — sweep 0 rides shapes
    the earlier engine cells already compiled while the delta sweeps
    pay every first-time small-batch compile — and the ratio measures
    compile churn, not the delta engine.
    """
    from repro.core.api import DiscoverySession
    from repro.core.score_common import ScoreConfig, config_key
    from repro.core.spec import EngineOptions

    def _schedule():
        base = [config_key(*c) for c in _frontier_configs(d)]
        frontier = list(base)
        for t in range(7):
            if t > 0:  # "apply a step" at node y: ~d new 2-parent configs
                y = (t - 1) % d
                fresh = list(dict.fromkeys(
                    config_key(y, (x, (x + t) % d))
                    for x in range(d)
                    if x != y and (x + t) % d not in (x, y)
                ))
                frontier = [k for k in frontier if k not in fresh] + fresh
            yield t, list(frontier)

    def _mk_sess():
        return DiscoverySession(
            data, config=ScoreConfig(seed=seed),
            options=EngineOptions(incremental=True),
            feature_bank=feature_bank,
        )

    warmup = _mk_sess()  # compiles every shape the timed pass will hit
    for _, frontier in _schedule():
        warmup.begin_sweep("bench")
        warmup.score_frontier(frontier)
        warmup.end_sweep(None)

    sess = _mk_sess()
    sweeps = []
    for t, frontier in _schedule():
        t0 = time.perf_counter()
        sess.begin_sweep("bench")
        sess.score_frontier(frontier)
        sess.end_sweep(None)
        dt = time.perf_counter() - t0
        rec = sess.sweep_log[-1]
        sweeps.append(
            {
                "sweep": t,
                "n_configs": len(frontier),
                **rec.get("frontier", {}),
                "elapsed_s": round(dt, 4),
                "configs_served_per_sec": round(len(frontier) / dt, 3),
            }
        )
    cold = sweeps[0]["configs_served_per_sec"]
    steady = sweeps[1:]
    warm = max(s["configs_served_per_sec"] for s in steady)
    return {
        "cold_sweep_configs_per_sec": cold,
        "warm_sweep_configs_per_sec": warm,
        "warm_vs_cold": round(warm / cold, 3),
        "sweeps": sweeps,
    }


def run(
    quick: bool = False, out_path: str = OUT_PATH, precision: bool = False
) -> dict:
    grid = (
        [(8, 1000), (16, 1000)]
        if quick
        else [(d, n) for n in (1000, 10000) for d in (8, 16, 32)]
    )
    cells = []
    print("d,n,n_configs,seq/s,batched/s,hostpath/s,speedup,max_rel_err")
    for d, n in grid:
        cell = _bench_cell(
            d, n, seq_cap=24 if n >= 10000 else 48, precision=precision
        )
        cells.append(cell)
        print(
            f"{d},{n},{cell['n_configs']},{cell['seq_scores_per_sec']},"
            f"{cell['batched_scores_per_sec']},"
            f"{cell['batched_hostpath_scores_per_sec']},{cell['speedup']},"
            f"{cell['max_rel_err']:.2e}"
            f",inc-warm={cell['incremental']['warm_sweep_configs_per_sec']}/s"
            f" ({cell['incremental']['warm_vs_cold']}x cold)"
            + (
                f",f32={cell['f32_gram']['cold_scores_per_sec']}/s"
                f",dev={cell['f32_gram']['max_rel_dev_vs_f64_oracle']:.2e}"
                if "f32_gram" in cell
                else ""
            )
        )
    result = {
        "benchmark": "frontier_scoring",
        "unit": "candidate-scores/sec",
        "engine": "device-resident fold pipeline (Gram banks + gather-fold)"
        " over fold-gram strips + z-shared cores (PR 3); precision policy"
        " via repro.core.spec.EngineOptions (PR 4)",
        "quick": quick,
        "cells": cells,
    }
    result = write_bench(out_path, result)
    print(f"wrote {out_path}")
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=OUT_PATH)
    ap.add_argument(
        "--precision",
        action="store_true",
        help="additionally benchmark the precision='f32_gram' engine policy"
        " and record its deviation vs the f64 oracle per cell",
    )
    ap.add_argument(
        "--check-speedup",
        type=float,
        default=None,
        metavar="X",
        help="fail (exit 1) unless every cell's batched/sequential speedup"
        " is >= X — the CI smoke gate against engine perf regressions",
    )
    ap.add_argument(
        "--check-warm-speedup",
        type=float,
        default=None,
        metavar="X",
        help="fail (exit 1) unless every cell's incremental warm-sweep rate"
        " is >= X times its cold full-frontier rate — the frontier-delta"
        " engine's CI perf gate",
    )
    args = ap.parse_args()
    result = run(quick=args.quick, out_path=args.out, precision=args.precision)
    if args.check_speedup is not None:
        slow = [
            (c["d"], c["n"], c["speedup"])
            for c in result["cells"]
            if c["speedup"] < args.check_speedup
        ]
        if slow:
            print(
                f"PERF REGRESSION: cells below {args.check_speedup}x: {slow}"
            )
            raise SystemExit(1)
        print(f"perf gate ok: all cells >= {args.check_speedup}x")
    if args.check_warm_speedup is not None:
        slow = [
            (c["d"], c["n"], c["incremental"]["warm_vs_cold"])
            for c in result["cells"]
            if c["incremental"]["warm_vs_cold"] < args.check_warm_speedup
        ]
        if slow:
            print(
                "PERF REGRESSION: incremental warm sweeps below "
                f"{args.check_warm_speedup}x cold: {slow}"
            )
            raise SystemExit(1)
        print(
            "warm-sweep gate ok: all cells >= "
            f"{args.check_warm_speedup}x cold"
        )
