"""GES frontier-scoring throughput: sequential per-candidate dispatch vs
the batched engine (feature bank + Gram-block cache + chunked fold algebra).

For each (d, n) cell the benchmark builds the sweep-1 GES frontier on
synthetic SCM data — every Insert(X, Y, {}) needs (y, {x}) and (y, {})
local scores, d^2 configurations total — and measures candidate-scores/sec
through both paths of the SAME scorer state (features prebuilt, jit warm,
so the comparison isolates the scoring engine).  Emits BENCH_frontier.json
at the repo root so future PRs track the trajectory.

``python -m benchmarks.frontier_scoring``            — full grid
``python -m benchmarks.frontier_scoring --quick``    — small cells only
``--check-speedup X``  — exit nonzero unless every cell's batched/seq
ratio is >= X (the CI perf-smoke gate: engine regressions fail loudly).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(REPO_ROOT, "BENCH_frontier.json")


def _frontier_configs(d: int):
    configs = [(y, ()) for y in range(d)]
    configs += [(y, (x,)) for x in range(d) for y in range(d) if x != y]
    return configs


def _bench_cell(d: int, n: int, seq_cap: int, seed: int = 0) -> dict:
    from repro.core.score_common import ScoreConfig, config_key
    from repro.core.score_lowrank import CVLRScorer
    from repro.data.synthetic import generate_scm_data

    ds = generate_scm_data(d=d, n=n, density=0.3, kind="continuous", seed=seed)
    configs = _frontier_configs(d)

    scorer = CVLRScorer(ds.data, config=ScoreConfig(seed=seed))
    # Feature bank built once, outside the timers: both paths read the same
    # cached factors, so the cell measures scoring engines, not ICL.
    t0 = time.perf_counter()
    for v in range(d):
        scorer.features((v,))
    t_features = time.perf_counter() - t0
    m_effs = [scorer.m_eff_log[(v,)] for v in range(d)]

    # -- sequential oracle path: one jit dispatch + host sync per config --
    seq_configs = configs[: min(seq_cap, len(configs))]
    scorer._compute(*config_key(*configs[0]))  # jit warmup (not timed)
    seq_scores = []
    t0 = time.perf_counter()
    for i, ps in seq_configs:
        seq_scores.append(scorer._compute(*config_key(i, ps)))
    t_seq = time.perf_counter() - t0
    rate_seq = len(seq_configs) / t_seq

    # -- batched engine, cold Gram cache (jit warmed on a half-size probe) --
    warm = CVLRScorer(ds.data, config=ScoreConfig(seed=seed))
    warm._feat_cache = scorer._feat_cache
    warm.m_eff_log = scorer.m_eff_log
    warm.prefetch(configs)  # compiles every chunk shape (not timed)

    cold = CVLRScorer(ds.data, config=ScoreConfig(seed=seed))
    cold._feat_cache = scorer._feat_cache
    cold.m_eff_log = scorer.m_eff_log
    t0 = time.perf_counter()
    n_done = cold.prefetch(configs)
    t_bat = time.perf_counter() - t0
    assert n_done == len(configs)
    rate_bat = len(configs) / t_bat

    # numerical agreement spot-check (engine == oracle)
    worst = 0.0
    for (i, ps), b in zip(seq_configs, seq_scores):
        a = cold._score_cache[config_key(i, ps)]
        worst = max(worst, abs(a - b) / max(1.0, abs(b)))

    return {
        "d": d,
        "n": n,
        "n_configs": len(configs),
        "n_seq_timed": len(seq_configs),
        "m_eff_range": [int(min(m_effs)), int(max(m_effs))],
        "feature_build_s": round(t_features, 4),
        "seq_scores_per_sec": round(rate_seq, 3),
        "batched_scores_per_sec": round(rate_bat, 3),
        "speedup": round(rate_bat / rate_seq, 3),
        "max_rel_err": worst,
        "gram_cache": cold.gram_cache.stats,
    }


def run(quick: bool = False, out_path: str = OUT_PATH) -> dict:
    grid = (
        [(8, 1000), (16, 1000)]
        if quick
        else [(d, n) for n in (1000, 10000) for d in (8, 16, 32)]
    )
    cells = []
    print("d,n,n_configs,seq/s,batched/s,speedup,max_rel_err")
    for d, n in grid:
        cell = _bench_cell(d, n, seq_cap=24 if n >= 10000 else 48)
        cells.append(cell)
        print(
            f"{d},{n},{cell['n_configs']},{cell['seq_scores_per_sec']},"
            f"{cell['batched_scores_per_sec']},{cell['speedup']},"
            f"{cell['max_rel_err']:.2e}"
        )
    result = {
        "benchmark": "frontier_scoring",
        "unit": "candidate-scores/sec",
        "engine": "fold-gram-strip + z-shared fold cores (PR 2)",
        "quick": quick,
        "cells": cells,
    }
    with open(out_path, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    print(f"wrote {out_path}")
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=OUT_PATH)
    ap.add_argument(
        "--check-speedup",
        type=float,
        default=None,
        metavar="X",
        help="fail (exit 1) unless every cell's batched/sequential speedup"
        " is >= X — the CI smoke gate against engine perf regressions",
    )
    args = ap.parse_args()
    result = run(quick=args.quick, out_path=args.out)
    if args.check_speedup is not None:
        slow = [
            (c["d"], c["n"], c["speedup"])
            for c in result["cells"]
            if c["speedup"] < args.check_speedup
        ]
        if slow:
            print(
                f"PERF REGRESSION: cells below {args.check_speedup}x: {slow}"
            )
            raise SystemExit(1)
        print(f"perf gate ok: all cells >= {args.check_speedup}x")
