"""Multi-tenant serving stress: latency, shedding, shared-bank sharing.

Grid over (tenants x shared-bank) cells, each cell a burst of discovery
requests through one `repro.serving.SessionManager`:

* **latency** — p50/p95 wall-clock per completed request, measured under
  contention (worker pool + shared-cache sweep serialization);
* **shed rate** — requests rejected by the bounded admission queue
  (structured `RequestShed`), never wedged;
* **sharing** — with a shared bank, identical-fingerprint tenants must
  trigger ZERO duplicate factor builds (single-flight + LRU; asserted,
  not just reported) vs the unshared column where every tenant rebuilds.

Every completed request's CPDAG/score is asserted bitwise-equal to the
solo uninterrupted reference — a fast wrong answer is a failure, not a
data point.

Emits BENCH_serving.json at the repo root.

``python -m benchmarks.serving_stress``            — full sizes
``python -m benchmarks.serving_stress --quick``    — CI smoke
Never run concurrently with the test suite (2-vCPU box; see
EXPERIMENTS.md).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from benchmarks._writer import write_bench

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(REPO_ROOT, "BENCH_serving.json")


def _chain_data(n, d, seed=0):
    rng = np.random.default_rng(seed)
    cols = [rng.standard_normal(n)]
    for _ in range(d - 1):
        cols.append(np.tanh(cols[-1]) + 0.4 * rng.standard_normal(n))
    return np.stack(cols, axis=1)


def _solo_reference(data, cfg):
    from repro.core.api import DiscoverySession

    return DiscoverySession(data, config=cfg).run()


def bench_cell(data, cfg, tenants, shared_bank, ref, max_concurrent=4):
    from repro.serving import (
        DiscoveryRequest,
        RequestShed,
        ServingOptions,
        SessionManager,
    )

    completed = shed = 0
    t0 = time.perf_counter()
    if shared_bank:
        # one manager, one bank: tenants share factors through it
        managers = [
            SessionManager(
                data,
                config=cfg,
                serving=ServingOptions(
                    max_concurrent=max_concurrent,
                    queue_limit=max(tenants, 4),
                ),
            )
        ]
        submit_to = [managers[0]] * tenants
    else:
        # no-sharing baseline: one manager (and one private bank) per
        # tenant, all in flight concurrently — every tenant rebuilds
        managers = [
            SessionManager(
                data, config=cfg, serving=ServingOptions(max_concurrent=1)
            )
            for _ in range(tenants)
        ]
        submit_to = managers
    try:
        tickets = []
        for i, mgr in enumerate(submit_to):
            try:
                tickets.append(mgr.submit(DiscoveryRequest(tenant=f"t{i}")))
            except RequestShed:
                shed += 1
        for t in tickets:
            res = t.result(timeout=600)
            completed += 1
            if not np.array_equal(res.cpdag, ref.cpdag) or res.score != ref.score:
                raise AssertionError(
                    f"tenant {t.tenant}: result differs from the solo "
                    "reference run under contention"
                )
        latencies = sorted(t.latency_s for t in tickets)
        builds = sum(m.feature_bank.stats["builds"] for m in managers)
        entries = sum(m.feature_bank.stats["entries"] for m in managers)
    finally:
        for m in managers:
            m.shutdown()
    wall_s = time.perf_counter() - t0

    def _pct(p):
        i = min(len(latencies) - 1, int(round(p * (len(latencies) - 1))))
        return round(latencies[i], 4)

    duplicate_builds = builds - entries
    if shared_bank and duplicate_builds != 0:
        raise AssertionError(
            f"shared bank saw {duplicate_builds} duplicate builds — "
            "single-flight dedup is broken"
        )
    row = {
        "tenants": tenants,
        "shared_bank": shared_bank,
        "completed": completed,
        "shed": shed,
        "shed_rate": round(shed / tenants, 3),
        "latency_p50_s": _pct(0.50),
        "latency_p95_s": _pct(0.95),
        "wall_s": round(wall_s, 3),
        "builds": builds,
        "duplicate_builds": int(duplicate_builds),
    }
    print(f"serving,cell,{json.dumps(row)}")
    return row


def bench_shed(data, cfg) -> dict:
    """Overload cell: more requests than pool+queue; the excess must shed
    with retry-after instead of queueing unboundedly."""
    from repro.core.runstate import FaultPlan
    from repro.serving import (
        DiscoveryRequest,
        RequestShed,
        ServingOptions,
        SessionManager,
    )

    serving = ServingOptions(max_concurrent=1, queue_limit=1)
    shed = []
    mgr = SessionManager(data, config=cfg, serving=serving)
    try:
        hog = mgr.submit(
            DiscoveryRequest(
                tenant="hog", fault_plan=FaultPlan(stall_sweep=(0, 1.0))
            )
        )
        time.sleep(0.2)
        tickets = []
        for i in range(6):
            try:
                tickets.append(mgr.submit(DiscoveryRequest(tenant=f"x{i}")))
            except RequestShed as exc:
                shed.append(exc.to_dict())
        hog.result(timeout=600)
        for t in tickets:
            t.result(timeout=600)
    finally:
        mgr.shutdown()
    if not shed:
        raise AssertionError("overload burst was never shed")
    row = {
        "offered": 7,
        "shed": len(shed),
        "retry_after_s_max": max(s["retry_after_s"] for s in shed),
    }
    print(f"serving,shed,{json.dumps(row)}")
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="CI smoke sizes")
    ap.add_argument("--out", default=OUT_PATH, help="output JSON path")
    args = ap.parse_args()

    from repro.core.score_common import ScoreConfig

    n, d = (120, 4) if args.quick else (400, 6)
    tenant_grid = (2, 4) if args.quick else (2, 4, 8)
    data = _chain_data(n, d)
    cfg = ScoreConfig(seed=0)
    ref = _solo_reference(data, cfg)  # also warms the jit caches

    cells = []
    for tenants in tenant_grid:
        for shared in (True, False):
            cells.append(bench_cell(data, cfg, tenants, shared, ref))
    shed_row = bench_shed(data, cfg)

    payload = {
        "quick": bool(args.quick),
        "n": n,
        "d": d,
        "cells": cells,
        "shed": shed_row,
    }
    write_bench(args.out, payload)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
