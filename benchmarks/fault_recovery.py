"""Fault-tolerance overhead + recovery fidelity (PR 6).

Three measured claims, each with the correctness side *asserted* (a
recovery that returns the wrong CPDAG is a failure, not a data point):

* **checkpoint overhead** — full discovery with sweep-granular
  `RunState` checkpointing (`EngineOptions(checkpoint_dir=...)`,
  ``checkpoint_every=1``) vs the same run without, so the cost of
  survivability is a number per sweep, not a claim;
* **kill + resume** — `FaultPlan(kill_at_sweep=k)` preempts the run at a
  sweep boundary; a ``resume="auto"`` session restores the newest
  committed checkpoint and replays the rest.  Reports restore latency
  and replay time, asserts the resumed CPDAG/trace/score equal the
  uninterrupted run's exactly;
* **shard death** — sharded engine with one worker killed from sweep 0
  (`FaultPlan(kill_shard=...)`) vs an undisturbed sharded run: survivor
  re-shard overhead, with bitwise-equal CPDAG asserted.

Emits BENCH_recovery.json at the repo root.

``python -m benchmarks.fault_recovery``            — full sizes
``python -m benchmarks.fault_recovery --quick``    — CI smoke
Never run concurrently with the test suite (2-vCPU box; see
EXPERIMENTS.md).
"""

from __future__ import annotations

import argparse
import json
import os
import shutil
import tempfile
import time

import numpy as np

from benchmarks._writer import write_bench

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(REPO_ROOT, "BENCH_recovery.json")


def _chain_data(n, d, seed=0):
    rng = np.random.default_rng(seed)
    cols = [rng.standard_normal(n)]
    for _ in range(d - 1):
        cols.append(np.tanh(cols[-1]) + 0.4 * rng.standard_normal(n))
    return np.stack(cols, axis=1)


def _session(data, cfg, **kw):
    from repro.core.api import DiscoverySession

    return DiscoverySession(data, config=cfg, **kw)


def _assert_equal_runs(res, ref, label):
    if not np.array_equal(res.cpdag, ref.cpdag):
        raise AssertionError(f"{label}: recovered CPDAG differs from reference")
    if [tuple(s) for s in res.trace] != [tuple(s) for s in ref.trace]:
        raise AssertionError(f"{label}: recovered trace differs from reference")
    if res.score != ref.score:
        raise AssertionError(f"{label}: recovered score differs from reference")


def bench_checkpoint_overhead(data, cfg) -> dict:
    from repro.core.spec import EngineOptions

    t0 = time.perf_counter()
    sess = _session(data, cfg, options=EngineOptions())
    ref = sess.run()
    plain_s = time.perf_counter() - t0
    sweeps = len(sess.sweep_log)

    ckpt_dir = tempfile.mkdtemp(prefix="bench_ckpt_")
    try:
        t0 = time.perf_counter()
        sess2 = _session(
            data, cfg,
            options=EngineOptions(checkpoint_dir=ckpt_dir, checkpoint_every=1),
        )
        res = sess2.run()
        ckpt_s = time.perf_counter() - t0
        n_ckpts = len(os.listdir(ckpt_dir))
    finally:
        shutil.rmtree(ckpt_dir, ignore_errors=True)
    _assert_equal_runs(res, ref, "checkpointed run")
    row = {
        "sweeps": sweeps,
        "plain_s": round(plain_s, 4),
        "checkpointed_s": round(ckpt_s, 4),
        "n_checkpoints": n_ckpts,
        "overhead_s_per_sweep": round((ckpt_s - plain_s) / max(sweeps, 1), 5),
        "overhead_pct": round((ckpt_s / plain_s - 1.0) * 100, 2),
    }
    print(f"recovery,checkpoint_overhead,{json.dumps(row)}")
    return row


def bench_kill_resume(data, cfg, kill_at=2) -> dict:
    from repro.core.api import causal_discover
    from repro.core.runstate import FaultPlan, InjectedFault
    from repro.core.spec import EngineOptions

    t0 = time.perf_counter()
    ref = causal_discover(data, config=cfg)
    uninterrupted_s = time.perf_counter() - t0

    ckpt_dir = tempfile.mkdtemp(prefix="bench_resume_")
    try:
        opts = EngineOptions(checkpoint_dir=ckpt_dir, checkpoint_every=1)
        t0 = time.perf_counter()
        try:
            causal_discover(
                data, config=cfg, options=opts,
                fault_plan=FaultPlan(kill_at_sweep=kill_at),
            )
            raise AssertionError("FaultPlan kill did not fire")
        except InjectedFault:
            pass
        killed_s = time.perf_counter() - t0

        t0 = time.perf_counter()
        sess = _session(data, cfg, options=opts, resume="auto")
        restore_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        res = sess.run()
        replay_s = time.perf_counter() - t0
    finally:
        shutil.rmtree(ckpt_dir, ignore_errors=True)
    _assert_equal_runs(res, ref, "resumed run")
    row = {
        "kill_at_sweep": kill_at,
        "resumed_from": sess.resumed_from,
        "uninterrupted_s": round(uninterrupted_s, 4),
        "killed_partial_s": round(killed_s, 4),
        "restore_s": round(restore_s, 4),
        "replay_s": round(replay_s, 4),
        "recovery_vs_uninterrupted_pct": round(
            ((killed_s + restore_s + replay_s) / uninterrupted_s - 1.0) * 100, 2
        ),
        # the resumed run's first frontier scores every config cold, a
        # batch shape the warmup never compiled — a real resumed process
        # pays that jit anyway, so it stays in the measurement
        "replay_includes_fresh_shape_jit": True,
    }
    print(f"recovery,kill_resume,{json.dumps(row)}")
    return row


def bench_shard_death(data, cfg, workers=3) -> dict:
    from repro.core.runstate import FaultPlan
    from repro.core.spec import EngineOptions

    t0 = time.perf_counter()
    sess = _session(
        data, cfg, options=EngineOptions(engine="sharded",
                                         shard_workers=workers),
    )
    ref = sess.run()
    healthy_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    sess2 = _session(
        data, cfg,
        options=EngineOptions(engine="sharded", shard_workers=workers,
                              shard_retries=1),
        fault_plan=FaultPlan(kill_shard=(workers - 1, 0)),
    )
    res = sess2.run()
    degraded_s = time.perf_counter() - t0
    _assert_equal_runs(res, ref, "survivor re-shard run")
    shard_recs = [r["shards"] for r in sess2.sweep_log if "shards" in r]
    row = {
        "workers": workers,
        "healthy_s": round(healthy_s, 4),
        "one_dead_s": round(degraded_s, 4),
        "reshard_overhead_pct": round((degraded_s / healthy_s - 1.0) * 100, 2),
        "resharded_slices": sum(r["resharded"] for r in shard_recs),
        "sweeps_with_reshard": len(shard_recs),
    }
    print(f"recovery,shard_death,{json.dumps(row)}")
    return row


def run(quick=False, out=OUT_PATH):
    from repro.core.score_common import ScoreConfig
    from repro.core.spec import EngineOptions

    n, d = (120, 4) if quick else (400, 6)
    cfg = ScoreConfig(q_folds=5, m_max=40) if quick else ScoreConfig()
    data = _chain_data(n, d, seed=0)
    # untimed warmup: pay one-time jit compilation for both engines here,
    # so the timed sections compare steady-state runs, not compile noise
    _session(data, cfg, options=EngineOptions()).run()
    _session(
        data, cfg, options=EngineOptions(engine="sharded", shard_workers=3)
    ).run()
    report = {
        "quick": quick,
        "n": n,
        "d": d,
        "checkpoint_overhead": bench_checkpoint_overhead(data, cfg),
        "kill_resume": bench_kill_resume(data, cfg),
        "shard_death": bench_shard_death(data, cfg),
    }
    report = write_bench(out, report)
    print(f"recovery,report={out}")
    return report


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="CI smoke sizes")
    ap.add_argument("--out", default=OUT_PATH)
    args = ap.parse_args()
    run(quick=args.quick, out=args.out)
