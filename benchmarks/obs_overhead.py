"""Observability overhead + timeline-fidelity benchmark (PR 10).

The span layer's contract has three legs, and this benchmark measures
all of them on real discovery runs:

1. **Identity** — `obs="off"`, `obs="metrics"` and `obs="trace"` produce
   bitwise-identical CPDAGs and scores on the same cell (an active
   recorder adds stage-boundary syncs, never arithmetic).
2. **Overhead** — wall-clock ratios metrics/off and trace/off on a
   jit-warm cell, plus the disabled-span microbench (one
   ``ContextVar.get`` + a shared no-op span; nanoseconds/span).
   ``obs="off"`` *is* the no-recorder baseline path, so the off column
   doubles as the regression reference future PRs diff against.
3. **Timeline fidelity** — the trace run's JSONL events pass
   `repro.obs.validate_events`, the Chrome/Perfetto export loads, compile
   spans are separated from execute spans (fresh shapes are scored under
   the recorder so jit cache misses fire), and the top-level stage spans
   (enumerate / features / gram / zcores / fold / select / constraint /
   checkpoint) cover >= ``--check-coverage`` of total sweep wall time.

``--quick`` runs the small cell only; the full run adds the paper-scale
d=32 / n=10k cell driven on the session seam (sweep 0 cold frontier +
incremental delta sweeps).  Gate flags (``--check-*``) exit nonzero on
violation — the CI observability job runs them.  Emits BENCH_obs.json
at the repo root.  Never run concurrently with the test suite.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from benchmarks._writer import write_bench

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(REPO_ROOT, "BENCH_obs.json")

# mutually non-overlapping top-of-sweep stage spans (nested spans —
# ci_batch, skeleton_level, shard, kernel dispatches — are excluded so
# nothing is double-counted)
TOP_STAGES = (
    "enumerate",
    "features",
    "gram",
    "zcores",
    "fold",
    "select",
    "constraint",
    "checkpoint",
)


def _chain_data(n: int, d: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    cols = [rng.standard_normal(n)]
    for _ in range(d - 1):
        cols.append(np.tanh(cols[-1]) + 0.4 * rng.standard_normal(n))
    return np.stack(cols, axis=1)


def _discover(data, obs: str, trace_dir=None):
    from repro.core.api import causal_discover
    from repro.core.spec import EngineOptions

    t0 = time.perf_counter()
    res = causal_discover(
        data, options=EngineOptions(obs=obs, trace_dir=trace_dir)
    )
    return res, time.perf_counter() - t0


def coverage(events) -> dict:
    """Stage-span wall coverage: sum of top-level stage spans over the
    sum of sweep spans (both in seconds)."""
    sweep_s = sum(
        e["dur"] for e in events if e.get("cat") == "sweep"
    ) / 1e6
    stage_s = sum(
        e["dur"]
        for e in events
        if e.get("cat") == "stage" and e.get("name") in TOP_STAGES
    ) / 1e6
    return {
        "sweep_s": round(sweep_s, 4),
        "stage_s": round(stage_s, 4),
        "ratio": round(stage_s / sweep_s, 4) if sweep_s else None,
    }


def noop_span_ns(iters: int = 200_000) -> float:
    """Cost of one `repro.obs.trace.span` with NO active recorder."""
    from repro.obs import trace as obs_trace

    assert obs_trace.get_recorder() is None
    t0 = time.perf_counter()
    for _ in range(iters):
        with obs_trace.span("bench"):
            pass
    return (time.perf_counter() - t0) / iters * 1e9


def bench_cell(d: int, n: int, trace_dir: str, reps: int = 3) -> dict:
    """Identity + overhead + fidelity on one causal_discover cell."""
    from repro.obs import read_jsonl, validate_events

    data = _chain_data(n, d, seed=0)
    # untimed warmup compiles every shape; the timed passes below compare
    # steady-state engines, not jit churn
    ref, _ = _discover(data, "off")

    times = {}
    for mode in ("off", "metrics", "trace"):
        kw = {"trace_dir": trace_dir} if mode == "trace" else {}
        best = None
        for _ in range(reps):
            res, dt = _discover(data, mode, **kw)
            best = dt if best is None else min(best, dt)
            assert (res.cpdag == ref.cpdag).all(), f"{mode}: CPDAG diverged"
            assert res.score == ref.score, f"{mode}: score diverged"
        times[mode] = best

    # fidelity: validate the newest trace pair written above
    jsonls = sorted(
        (f for f in os.listdir(trace_dir) if f.endswith(".jsonl")),
        key=lambda f: os.path.getmtime(os.path.join(trace_dir, f)),
    )
    events = read_jsonl(os.path.join(trace_dir, jsonls[-1]))
    errors = validate_events(events)
    assert not errors, f"invalid trace events: {errors[:5]}"
    chrome = [
        f for f in os.listdir(trace_dir)
        if f.endswith(".json") and jsonls[-1][len("events-"):-len(".jsonl")] in f
    ]
    with open(os.path.join(trace_dir, chrome[0])) as fh:
        loaded = json.load(fh)
    assert loaded["traceEvents"], "empty Chrome trace"

    names = {e["name"] for e in events}
    compile_spans = sum(1 for e in events if e.get("cat") == "compile")
    return {
        "d": d,
        "n": n,
        "wall_s": {k: round(v, 4) for k, v in times.items()},
        "metrics_over_off": round(times["metrics"] / times["off"], 4),
        "trace_over_off": round(times["trace"] / times["off"], 4),
        "events": len(events),
        "compile_spans": compile_spans,
        "has_session_sweep_stage": (
            "session" in {e["cat"] for e in events}
            and any(e["cat"] == "sweep" for e in events)
            and any(e["cat"] == "stage" for e in events)
        ),
        "coverage": coverage(events),
    }


def bench_seam_cell(d: int, n: int, trace_dir: str, sweeps: int = 3) -> dict:
    """The paper-scale trace cell, driven on the session seam: sweep 0 is
    the cold full frontier (d^2 configs), later sweeps are incremental
    deltas — the shape of a real GES run without its full wall cost."""
    from repro.core.api import DiscoverySession
    from repro.core.score_common import config_key
    from repro.core.spec import EngineOptions
    from repro.obs import validate_events

    data = _chain_data(n, d, seed=0)
    configs = [(y, ()) for y in range(d)]
    configs += [(y, (x,)) for x in range(d) for y in range(d) if x != y]
    frontier = [config_key(*c) for c in configs]

    sess = DiscoverySession(
        data, options=EngineOptions(obs="trace", trace_dir=trace_dir)
    )
    rec = sess.recorder
    t0 = time.perf_counter()
    with rec.activate():
        for t in range(sweeps):
            if t > 0:
                y = (t - 1) % d
                fresh = [
                    config_key(y, (x, (x + t) % d))
                    for x in range(d)
                    if x != y and (x + t) % d not in (x, y)
                ]
                frontier = [
                    k for k in frontier if k not in set(fresh)
                ] + list(dict.fromkeys(fresh))
            sess.begin_sweep("bench")
            sess.score_frontier(frontier)
            sess.end_sweep(None)
    wall = time.perf_counter() - t0
    events = rec.events()
    errors = validate_events(events)
    assert not errors, f"invalid trace events: {errors[:5]}"
    sess.close_obs()  # writes the Perfetto file
    return {
        "d": d,
        "n": n,
        "sweeps": sweeps,
        "n_configs_cold": len(configs),
        "wall_s": round(wall, 4),
        "events": len(events),
        "compile_spans": sum(1 for e in events if e.get("cat") == "compile"),
        "coverage": coverage(events),
    }


def run(
    quick: bool = False, out_path: str = OUT_PATH, trace_dir: str | None = None
) -> dict:
    import tempfile

    if trace_dir is None:
        trace_dir = tempfile.mkdtemp(prefix="obs_overhead_")
    os.makedirs(trace_dir, exist_ok=True)

    cell = bench_cell(6, 400, trace_dir, reps=2 if quick else 3)
    print(f"obs,cell,{json.dumps(cell)}")
    result = {
        "benchmark": "obs_overhead",
        "unit": "wall-clock ratio vs obs=off / ns per disabled span",
        "engine": "repro.obs span layer + MetricsRegistry over the "
        "batched CV-LR discovery stack (PR 10)",
        "quick": quick,
        "noop_span_ns": round(noop_span_ns(), 1),
        "cell": cell,
        "trace_dir": trace_dir,
    }
    if not quick:
        seam = bench_seam_cell(32, 10_000, trace_dir)
        print(f"obs,seam,{json.dumps(seam)}")
        result["paper_scale"] = seam
    result = write_bench(out_path, result)
    print(f"wrote {out_path}")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=OUT_PATH)
    ap.add_argument("--trace-dir", default=None)
    ap.add_argument(
        "--check-coverage", type=float, default=None,
        help="exit nonzero unless stage spans cover >= this fraction of "
        "sweep wall time in the trace run",
    )
    ap.add_argument(
        "--check-metrics-overhead", type=float, default=None,
        help="exit nonzero unless metrics/off wall ratio <= this bound",
    )
    ap.add_argument(
        "--check-noop-ns", type=float, default=None,
        help="exit nonzero unless a disabled span costs <= this many ns",
    )
    args = ap.parse_args()
    result = run(quick=args.quick, out_path=args.out, trace_dir=args.trace_dir)

    failures = []
    cov = result["cell"]["coverage"]["ratio"]
    if "paper_scale" in result:
        cov = result["paper_scale"]["coverage"]["ratio"]
    if args.check_coverage is not None and cov < args.check_coverage:
        failures.append(
            f"stage-span coverage {cov} < required {args.check_coverage}"
        )
    if (
        args.check_metrics_overhead is not None
        and result["cell"]["metrics_over_off"] > args.check_metrics_overhead
    ):
        failures.append(
            f"metrics/off ratio {result['cell']['metrics_over_off']} > "
            f"bound {args.check_metrics_overhead}"
        )
    if (
        args.check_noop_ns is not None
        and result["noop_span_ns"] > args.check_noop_ns
    ):
        failures.append(
            f"disabled span costs {result['noop_span_ns']}ns > "
            f"bound {args.check_noop_ns}"
        )
    if result["cell"]["compile_spans"] == 0:
        # the warmup runs off-recorder, but the traced pass still sees
        # python-side retrace events on fresh callables in most runs;
        # only hard-fail when gating was requested
        print("obs,warn,no compile spans captured in the quick cell")
    for f in failures:
        print(f"obs,FAIL,{f}")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
