"""Roofline derivation from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads benchmarks/dryrun_results/<mesh>/<arch>__<shape>.json and emits, per
cell:

    compute_s    = HLO_FLOPs_per_device / PEAK_FLOPS_BF16
    memory_s     = HLO_bytes_per_device / HBM_BW
    collective_s = collective_bytes_per_device / ICI_BW
    bottleneck   = argmax of the three
    model_flops  = 6*N*D (train, dense) / 6*N_active*D (MoE) /
                   2*N*D (+2*N_active*D) for serve steps
    useful_ratio = model_flops_per_device / HLO_FLOPs_per_device

cost_analysis() numbers are PER DEVICE post-SPMD (verified against
hand-partitioned matmuls), so peak terms use single-chip constants.
"""

from __future__ import annotations

import json
import os

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.models.config import SHAPES

RESULTS = os.path.join(os.path.dirname(__file__), "dryrun_results")


def model_flops_per_device(arch: str, shape_name: str, mesh_shape: dict) -> float:
    """Analytic 'useful' FLOPs per device for the cell."""
    from repro.models.registry import load_arch, param_count_exact

    if arch == "cvlr_paper":
        from repro.configs.cvlr_paper import config

        w = config()
        n = w.q_folds * w.samples_per_fold
        # Gram blocks: 6 contractions of (n x m)^T(n x m) per candidate
        flops = w.num_candidates * 6 * 2 * n * w.m * w.m
        return flops / _chips(mesh_shape)

    cfg, model = load_arch(arch)
    n_total = param_count_exact(model)
    n_active = (
        n_total
        - (cfg.num_experts - cfg.num_experts_per_tok)
        * (3 if cfg.mlp_kind in ("swiglu", "geglu") else 2)
        * cfg.d_model
        * cfg.d_ff
        * cfg.num_layers
        if cfg.num_experts
        else n_total
    )
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        flops = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        flops = 2.0 * n_active * tokens
    else:  # decode: one token per sequence
        flops = 2.0 * n_active * shape.global_batch
    return flops / _chips(mesh_shape)


def _chips(mesh_shape: dict) -> int:
    n = 1
    for v in mesh_shape.values():
        n *= v
    return n


def analytic_hbm_bytes_per_device(arch: str, shape_name: str, mesh_shape: dict) -> float:
    """First-order HBM traffic model (what a fused TPU executable moves):

    train:   3x params (fwd read, bwd read, update rw) + opt state rw
             + activations ~ tokens * L * (6E + 3F_act + 4HD) * 2B * 1.5(remat)
    prefill: 1x params + activations (no remat factor)
    decode:  1x params + full KV/state cache read + tiny activations

    XLA:CPU's `bytes accessed` counts every op's operands pre-fusion and
    overstates this by ~10-50x; both are reported (EXPERIMENTS.md §Roofline).
    """
    from repro.models.registry import load_arch, param_count_exact

    chips = _chips(mesh_shape)
    if arch == "cvlr_paper":
        from repro.configs.cvlr_paper import config

        w = config()
        n = w.q_folds * w.samples_per_fold
        # factors streamed once per candidate batch (2 tensors, f64)
        return w.num_candidates * 2 * n * w.m * 8 / chips

    cfg, model = load_arch(arch)
    shape = SHAPES[shape_name]
    n_params = param_count_exact(model)
    p_bytes = 2.0 * n_params  # bf16
    e, f, hd = cfg.d_model, max(cfg.d_ff, 2 * cfg.d_model), cfg.resolved_head_dim
    act_per_tok_layer = (6 * e + 3 * (f if not cfg.num_experts else f * cfg.num_experts_per_tok) + 4 * cfg.num_heads * hd) * 2.0
    layers = cfg.num_layers + cfg.enc_layers
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return (3.5 * p_bytes + 1.5 * tokens * layers * act_per_tok_layer) / chips
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return (p_bytes + tokens * layers * act_per_tok_layer) / chips
    # decode: read params + the whole cache once per token
    kv_bytes = (
        layers * shape.global_batch * shape.seq_len
        * cfg.num_kv_heads * hd * 2 * 2.0
    )
    if cfg.family in ("ssm", "hybrid"):
        kv_bytes = shape.global_batch * layers * (2 * e) * max(cfg.ssm_state, 64) * 4.0
    return (p_bytes + kv_bytes) / chips


def roofline_row(record: dict) -> dict:
    if record.get("status") != "ok":
        return {**record, "bottleneck": "ERROR"}
    compute_s = record["flops"] / PEAK_FLOPS_BF16
    memory_s = record["bytes_accessed"] / HBM_BW
    coll_b = record["collectives"]["total_collective_bytes"]
    collective_s = coll_b / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops_per_device(
        record["arch"], record["shape"], record["mesh_shape"]
    )
    amem = analytic_hbm_bytes_per_device(
        record["arch"], record["shape"], record["mesh_shape"]
    )
    analytic_memory_s = amem / HBM_BW
    # bottleneck judged with the fused-traffic (analytic) memory estimate;
    # the raw HLO term is reported alongside (EXPERIMENTS.md §Roofline).
    terms_eff = {
        "compute": compute_s,
        "memory": analytic_memory_s,
        "collective": collective_s,
    }
    bottleneck = max(terms_eff, key=terms_eff.get)
    step_s = max(terms_eff.values())  # no-overlap upper bound on step time
    mfu = (mf / PEAK_FLOPS_BF16) / step_s if step_s > 0 else 0.0
    return {
        "arch": record["arch"],
        "shape": record["shape"],
        "mesh": record["mesh"],
        "compute_s": compute_s,
        "memory_s": memory_s,
        "analytic_memory_s": analytic_memory_s,
        "collective_s": collective_s,
        "bottleneck": bottleneck,
        "model_flops_dev": mf,
        "hlo_flops_dev": record["flops"],
        "useful_ratio": mf / record["flops"] if record["flops"] else 0.0,
        "roofline_fraction": mfu,
        "hbm_bytes_dev": record["memory"].get("argument_size_in_bytes", 0)
        + record["memory"].get("temp_size_in_bytes", 0),
        "ar_count": record["collectives"].get("all-reduce_count", 0),
        "a2a_count": record["collectives"].get("all-to-all_count", 0),
    }


def load_rows(mesh: str = "single"):
    out = []
    d = os.path.join(RESULTS, mesh)
    if not os.path.isdir(d):
        return out
    for name in sorted(os.listdir(d)):
        if name.endswith(".json"):
            with open(os.path.join(d, name)) as f:
                out.append(roofline_row(json.load(f)))
    return out


def format_table(rows) -> str:
    hdr = (
        f"{'arch':22s} {'shape':12s} {'compute_s':>9s} {'hlo_mem_s':>9s} "
        f"{'mem_s':>8s} {'coll_s':>8s} {'bound':>10s} {'useful':>7s} {'roofline%':>9s}"
    )
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        if r.get("bottleneck") == "ERROR":
            lines.append(f"{r['arch']:22s} {r['shape']:12s} ERROR: {r.get('error','')[:60]}")
            continue
        lines.append(
            f"{r['arch']:22s} {r['shape']:12s} {r['compute_s']:9.4f} "
            f"{r['memory_s']:9.3f} {r['analytic_memory_s']:8.4f} "
            f"{r['collective_s']:8.4f} {r['bottleneck']:>10s} "
            f"{r['useful_ratio']:7.2f} {100*r['roofline_fraction']:8.1f}%"
        )
    return "\n".join(lines)


def main():
    for mesh in ("single", "multi"):
        rows = load_rows(mesh)
        if rows:
            print(f"\n=== Roofline ({mesh}-pod) ===")
            print(format_table(rows))


if __name__ == "__main__":
    main()
