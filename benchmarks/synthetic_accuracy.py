"""Paper Figs. 2-4: F1/SHD of recovered causal graphs on synthetic SCM data
(continuous / mixed / multi-dimensional) across densities and sample sizes,
CV-LR vs exact CV."""

from __future__ import annotations

import numpy as np

from repro.core.api import DataSpec, causal_discover
from repro.core.metrics import shd_cpdag, skeleton_f1
from repro.core.graph import dag_to_cpdag
from repro.core.score_common import ScoreConfig
from repro.data.synthetic import generate_scm_data


def run(
    kinds=("continuous", "mixed", "multidim"),
    densities=(0.2, 0.5, 0.8),
    ns=(200,),
    reps=3,
    d=7,
    methods=("cvlr", "cv"),
    quick=False,
):
    if quick:
        kinds, densities, ns, reps, methods = ("continuous",), (0.4,), (200,), 1, ("cvlr",)
    rows = []
    for kind in kinds:
        for dens in densities:
            for n in ns:
                for method in methods:
                    f1s, shds = [], []
                    for rep in range(reps):
                        ds = generate_scm_data(
                            d=d, n=n, density=dens, kind=kind, seed=100 * rep + 7
                        )
                        spec = DataSpec.from_arrays(
                            ds.data, dims=ds.dims, discrete=ds.discrete
                        )
                        res = causal_discover(
                            ds.data,
                            method=method,
                            spec=spec,
                            config=ScoreConfig(seed=rep),
                        )
                        f1s.append(skeleton_f1(res.cpdag, ds.dag))
                        shds.append(shd_cpdag(res.cpdag, dag_to_cpdag(ds.dag)))
                    rows.append(
                        dict(
                            kind=kind, density=dens, n=n, method=method,
                            f1=float(np.mean(f1s)), shd=float(np.mean(shds)),
                        )
                    )
                    print(
                        f"figs234,{kind},density={dens},n={n},{method},"
                        f"f1={np.mean(f1s):.3f},shd={np.mean(shds):.3f}"
                    )
    return rows


if __name__ == "__main__":
    run()
